"""Model-zoo library ops — the general trace→pipeline path.

The paper's headline promise is acceleration *without user intervention*:
trace an unmodified program, recover the causal call graph, and build the
mixed pipeline automatically.  :mod:`repro.models.harris` proves that for
the paper's own vision demo; this module generalizes it to the LM model
zoo.  Every layer-level building block (attention, rmsnorm, matmul/FFN,
MoE dispatch, RWKV token-shift, SSM scan) becomes a ModuleDatabase row
behind the interposable :class:`~repro.core.tracer.Library`, so a
transformer forward pass written against ``lib.*`` — with its weights held
in an ordinary Python closure, exactly like a loaded checkpoint — traces
into a :class:`~repro.core.ir.CourierIR` that the Pipeline Generator can
partition, fuse (the registered rmsnorm+matmul mega-kernel), replicate,
verify, and serve.

All software impls operate on rank-2 ``[T, d]`` activations (one sequence
per pipeline token): that is the granularity the tracer observes, and it
keeps the rmsnorm module's shape gate (``len(shape) == 2``) satisfied so
fusion fires on the traced graph.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import (NodeCost, elementwise_cost, matmul_cost)
from repro.core.database import ModuleDatabase
from repro.kernels.ops import register_rmsnorm_matmul_modules

__all__ = ["make_zoo_db", "transformer_demo", "init_transformer_params",
           "recurrent_demo", "init_recurrent_params",
           "make_decode_attention", "register_decode_modules"]


# --------------------------------------------------------------------------- #
# Software implementations (the "original binary" the Frontend interposes on)
# --------------------------------------------------------------------------- #
def sw_attention(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array,
                 wo: jax.Array, *, n_heads: int,
                 theta: float = 10000.0) -> jax.Array:
    """Causal self-attention with RoPE over one sequence. x: [T, d]."""
    T, d = x.shape
    hd = d // n_heads
    q = (x @ wq).reshape(T, n_heads, hd)
    k = (x @ wk).reshape(T, n_heads, hd)
    v = (x @ wv).reshape(T, n_heads, hd)
    q, k = _rope(q, theta), _rope(k, theta)
    s = jnp.einsum("thi,mhi->htm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    mask = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    y = jnp.einsum("htm,mhi->thi", p, v.astype(jnp.float32))
    return (y.reshape(T, d).astype(x.dtype)) @ wo


def _rope(x: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding; x: [T, H, hd]."""
    T, H, hd = x.shape
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(T, dtype=jnp.float32)[:, None] * freq       # [T, half]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _rope_at(x: jax.Array, pos: int, theta: float) -> jax.Array:
    """Rotary embedding of ONE token at absolute position ``pos``;
    x: [1, H, hd].  Bit-matches row ``pos`` of :func:`_rope` over the full
    prefix (same fp32 angle math), which is what makes incremental decode
    agree with the re-run-the-prefix baseline."""
    half = x.shape[-1] // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.float32(pos) * freq                                # [half]
    cos, sin = jnp.cos(ang)[None, None, :], jnp.sin(ang)[None, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def make_decode_attention(pool: Any, *, n_heads: int,
                          theta: float = 10000.0,
                          k_buf: str = "k", v_buf: str = "v") -> Callable:
    """Incremental decode attention over a KV slot pool (STATEFUL).

    Returns ``attn(x, slot, wq, wk, wv, wo) -> [1, d]``: one new token
    ``x: [1, d]`` plus its request's ``slot`` id (scalar; ``-1`` = dead
    row).  The op reads the slot's cached (rotated) keys/values, projects
    and RoPE-rotates the new token at absolute position ``len(slot)``,
    appends its k/v row to the cache, and attends over cache + self — an
    O(prefix) step instead of the O(prefix²) full-prefix re-run, and
    bit-identical to :func:`sw_attention` on the accumulated prefix (the
    per-row unit test asserts it).

    Host-side state: the impl must run UNJITTED and serially — register it
    with ``state=`` (see :func:`register_decode_modules`) so the tracer
    marks the node ``serial_only`` and the backend keeps the stage off the
    jit/vmap/fusion paths.  A dead row (``slot < 0``) appends nothing and
    attends over only itself, so padding/evicted seats in a continuously
    batched group are harmless no-ops on the pool.
    """
    def attention_decode(x: jax.Array, slot: Any, wq: jax.Array,
                         wk: jax.Array, wv: jax.Array,
                         wo: jax.Array) -> jax.Array:
        d = x.shape[-1]
        hd = d // n_heads
        s_id = int(np.asarray(slot))
        pos = pool.length(s_id)
        q = (x @ wq).reshape(1, n_heads, hd)
        k = (x @ wk).reshape(1, n_heads, hd)
        v = (x @ wv).reshape(1, n_heads, hd)
        q, k = _rope_at(q, pos, theta), _rope_at(k, pos, theta)
        cache = pool.read(s_id)
        pool.append(s_id, **{k_buf: np.asarray(k[0]),
                             v_buf: np.asarray(v[0])})
        K = jnp.concatenate(
            [jnp.asarray(cache[k_buf], dtype=x.dtype), k], axis=0)
        V = jnp.concatenate(
            [jnp.asarray(cache[v_buf], dtype=x.dtype), v], axis=0)
        s = jnp.einsum("thi,mhi->htm", q.astype(jnp.float32),
                       K.astype(jnp.float32)) / np.sqrt(hd)
        # causality is structural: the cache holds only positions < pos
        p = jax.nn.softmax(s, axis=-1)
        y = jnp.einsum("htm,mhi->thi", p, V.astype(jnp.float32))
        return (y.reshape(1, d).astype(x.dtype)) @ wo

    attention_decode.__name__ = "attention_decode"
    return attention_decode


def register_decode_modules(db: ModuleDatabase, pool: Any, *,
                            n_heads: int, theta: float = 10000.0,
                            name: str = "attention_decode",
                            state: str = "kv") -> None:
    """Register the stateful incremental-decode attention row.

    ``state=`` marks the row stateful: the tracer threads it onto the
    traced ``Node.state`` (implying ``serial_only``), the backend runs its
    stage unjitted, and fusion/replication/hw placement all refuse it (the
    ``state-slot`` verify rule enforces the same).  Multi-layer models
    register one row per layer, each with its own pool.
    """
    db.register(name, software=make_decode_attention(
        pool, n_heads=n_heads, theta=theta),
        cost_sw=_c_attn, tags=("zoo", "decode"), state=state)


def sw_add(a: jax.Array, b: jax.Array) -> jax.Array:
    """Residual add."""
    return a + b


def sw_swiglu(x: jax.Array, wi: jax.Array, wo: jax.Array) -> jax.Array:
    """SwiGLU FFN. x: [T, d], wi: [d, 2*ff], wo: [ff, d]."""
    h = x @ wi
    g, u = jnp.split(h, 2, axis=-1)
    return (jax.nn.silu(g) * u) @ wo


def sw_moe(x: jax.Array, gate_w: jax.Array, w_in: jax.Array,
           w_out: jax.Array, *, top_k: int = 2) -> jax.Array:
    """Top-k MoE dispatch (dense einsum form). x: [T, d], gate_w: [d, E],
    w_in: [E, d, ff], w_out: [E, ff, d]."""
    logits = (x @ gate_w).astype(jnp.float32)                    # [T, E]
    E = logits.shape[-1]
    kth = jnp.sort(logits, axis=-1)[:, E - top_k][:, None]
    probs = jax.nn.softmax(jnp.where(logits >= kth, logits, -jnp.inf),
                           axis=-1)                              # [T, E]
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x, w_in))
    y = jnp.einsum("tef,efd->ted", h, w_out)
    return jnp.einsum("te,ted->td", probs, y).astype(x.dtype)


def sw_rwkv_shift(x: jax.Array, mu: jax.Array) -> jax.Array:
    """RWKV token-shift mix: blend each token with its predecessor.
    x: [T, d], mu: [d]."""
    prev = jnp.concatenate([jnp.zeros_like(x[:1]), x[:-1]], axis=0)
    return x + (prev - x) * mu


def sw_ssm_scan(x: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array) -> jax.Array:
    """Diagonal linear state-space scan: h_t = a*h + b*x_t; y_t = c*h_t.
    x: [T, d]; a, b, c: [d] with a in (0, 1)."""
    def step(h, x_t):
        h = a * h + b * x_t
        return h, c * h
    _, y = jax.lax.scan(step, jnp.zeros_like(x[0]), x)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Cost providers (the synthesis-report analog for the sw rows)
# --------------------------------------------------------------------------- #
def _c_attn(shapes, dtypes, params) -> NodeCost:
    (T, d) = shapes[0]
    proj = matmul_cost(T, d, d, bytes_per_el=4, batch=4)   # q/k/v/o projections
    mix = matmul_cost(T, T, d, bytes_per_el=4, batch=2)    # QK^T and PV
    return NodeCost(flops=proj.flops + mix.flops,
                    bytes_rw=proj.bytes_rw + mix.bytes_rw)


def _c_add(shapes, dtypes, params) -> NodeCost:
    return elementwise_cost(int(np.prod(shapes[0])), bytes_per_el=4)


def _c_swiglu(shapes, dtypes, params) -> NodeCost:
    (T, d), (_, two_ff) = shapes[0], shapes[1]
    ff = two_ff // 2
    up = matmul_cost(T, two_ff, d, bytes_per_el=4)
    down = matmul_cost(T, d, ff, bytes_per_el=4)
    return NodeCost(flops=up.flops + down.flops,
                    bytes_rw=up.bytes_rw + down.bytes_rw)


def _c_moe(shapes, dtypes, params) -> NodeCost:
    (T, d), (_, E) = shapes[0], shapes[1]
    ff = shapes[2][2]
    expert = matmul_cost(T, ff, d, bytes_per_el=4, batch=2 * E)
    return NodeCost(flops=expert.flops, bytes_rw=expert.bytes_rw)


def _c_scan(shapes, dtypes, params) -> NodeCost:
    return elementwise_cost(int(np.prod(shapes[0])), flops_per_el=4,
                            bytes_per_el=4, n_operands=4)


# --------------------------------------------------------------------------- #
# The zoo database
# --------------------------------------------------------------------------- #
def make_zoo_db() -> ModuleDatabase:
    """ModuleDatabase with every model-zoo layer op registered.

    rmsnorm / matmul / the fused rmsnorm+matmul mega-kernel come from
    :func:`repro.kernels.ops.register_rmsnorm_matmul_modules` — the same
    rows the fusion benchmark exercises, now reachable from a trace.  The
    remaining ops are software rows (database miss → sw placement), which
    is what keeps the traced graph *mixed*: hw islands separated by sw
    nodes, exactly the shape the partitioner and fusion pass must handle.
    """
    db = ModuleDatabase("zoo")
    register_rmsnorm_matmul_modules(db)
    db.register("attention", software=sw_attention, cost_sw=_c_attn,
                tags=("zoo",))
    db.register("add", software=sw_add, cost_sw=_c_add, tags=("zoo",))
    db.register("swiglu", software=sw_swiglu, cost_sw=_c_swiglu,
                tags=("zoo",))
    db.register("moe", software=sw_moe, cost_sw=_c_moe, tags=("zoo",))
    db.register("rwkv_shift", software=sw_rwkv_shift, cost_sw=_c_scan,
                tags=("zoo",))
    db.register("ssm_scan", software=sw_ssm_scan, cost_sw=_c_scan,
                tags=("zoo",))
    return db


# --------------------------------------------------------------------------- #
# Demo apps (unmodified user code over the interposable Library)
# --------------------------------------------------------------------------- #
def init_transformer_params(key: jax.Array, *, n_layers: int = 2,
                            d: int = 128, ff: int = 256, n_heads: int = 4,
                            vocab: int = 512) -> dict:
    """Random checkpoint for :func:`transformer_demo` (float32)."""
    def dense(k, shape):
        fan_in = shape[0]
        return (jax.random.normal(k, shape, jnp.float32) * fan_in ** -0.5)

    keys = iter(jax.random.split(key, 6 * n_layers + 2))
    layers = []
    for _ in range(n_layers):
        layers.append({
            "ln1": jnp.zeros((d,), jnp.float32),
            "wq": dense(next(keys), (d, d)),
            "wk": dense(next(keys), (d, d)),
            "wv": dense(next(keys), (d, d)),
            "wo": dense(next(keys), (d, d)),
            "ln2": jnp.zeros((d,), jnp.float32),
            "wi": dense(next(keys), (d, 2 * ff)),
            "wo_ffn": dense(next(keys), (ff, d)),
        })
    return {"layers": layers, "n_heads": n_heads, "theta": 10000.0,
            "ln_f": jnp.zeros((d,), jnp.float32),
            "w_out": dense(next(keys), (d, vocab))}


def transformer_demo(lib: Any, params: dict) -> Callable:
    """Pre-norm transformer forward over ``lib.*`` calls; weights closed over.

    The returned ``app(x)`` is the "unmodified binary": it never mentions
    tracing, placement, or pipelines.  Every weight reaches the Frontend as
    a mid-trace first sighting (a captured graph input), and the final
    ``rmsnorm → matmul`` (lm head) pair is the branch-free hw run the
    fusion pass collapses into the registered mega-kernel.
    """
    n_heads = int(params["n_heads"])
    theta = float(params["theta"])

    def app(x: jax.Array) -> jax.Array:          # x: [T, d] embeddings
        for ly in params["layers"]:
            h = lib.rmsnorm(x, ly["ln1"])
            a = lib.attention(h, ly["wq"], ly["wk"], ly["wv"], ly["wo"],
                              n_heads=n_heads, theta=theta)
            x = lib.add(x, a)
            h = lib.rmsnorm(x, ly["ln2"])
            f = lib.swiglu(h, ly["wi"], ly["wo_ffn"])
            x = lib.add(x, f)
        h = lib.rmsnorm(x, params["ln_f"])
        return lib.matmul(h, params["w_out"])    # logits [T, vocab]

    app.__name__ = "transformer"
    return app


def init_recurrent_params(key: jax.Array, *, d: int = 64) -> dict:  # lint: allow-dead(traced-demo API exercised by benchmarks/tests)
    """Random weights for :func:`recurrent_demo` (RWKV shift + SSM scan)."""
    k1, k2 = jax.random.split(key)
    return {"mu": jax.random.uniform(k1, (d,), jnp.float32, 0.1, 0.9),
            "a": jax.random.uniform(k2, (d,), jnp.float32, 0.5, 0.95),
            "b": jnp.ones((d,), jnp.float32),
            "c": jnp.ones((d,), jnp.float32),
            "ln": jnp.zeros((d,), jnp.float32)}


def recurrent_demo(lib: Any, params: dict) -> Callable:  # lint: allow-dead(traced-demo API exercised by benchmarks/tests)
    """Minimal RWKV/SSM-style block: shift-mix → norm → scan → residual."""
    def app(x: jax.Array) -> jax.Array:          # x: [T, d]
        h = lib.rwkv_shift(x, params["mu"])
        h = lib.rmsnorm(h, params["ln"])
        y = lib.ssm_scan(h, params["a"], params["b"], params["c"])
        return lib.add(x, y)

    app.__name__ = "recurrent"
    return app
