"""The paper's case-study workload (Sect. IV): cornerHarris_Demo.

OpenCV processing flow on a 1920×1080 frame:

    cvtColor → cornerHarris → normalize → convertScaleAbs

Pure-jnp "software" implementations below are the DB fallbacks (the paper's
"functions run on CPU"); ``repro.kernels.harris`` registers the Pallas
"hardware modules" for cvtColor / cornerHarris / convertScaleAbs — and, as
in the paper, **normalize has no hardware module** and stays in software.

The functions mirror the OpenCV semantics used by the demo:
  * cvtColor: BT.601 RGB→gray
  * cornerHarris(blockSize=2, ksize=3, k=0.04): Sobel gradients, box-filtered
    second-moment matrix, response R = det(M) − k·trace(M)²
  * normalize: NORM_MINMAX to [0, 255]
  * convertScaleAbs: |αx + β| saturated to [0, 255]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import (NodeCost, elementwise_cost, fused_cost,
                                  stencil_cost)
from repro.core.database import ModuleDatabase


# --------------------------------------------------------------------------- #
# software implementations (pure jnp)
# --------------------------------------------------------------------------- #
def cvt_color(img: jax.Array) -> jax.Array:
    """RGB [H, W, 3] → gray [H, W] float32 (BT.601)."""
    w = jnp.asarray([0.299, 0.587, 0.114], jnp.float32)
    return jnp.einsum("hwc,c->hw", img.astype(jnp.float32), w)


def corner_harris(gray: jax.Array, block_size: int = 2, k: float = 0.04) -> jax.Array:
    """Sobel gradients → box-filtered second moments → Harris response.

    Border convention: the image is edge-padded ONCE by the full stencil
    reach (sobel + box), and both stages then run "valid" — identical to
    the Pallas module's halo-block scheme, so kernel vs. ref is exact.
    """
    H, W = gray.shape
    halo = 1 + block_size // 2
    g = jnp.pad(gray, ((halo, halo + block_size - 1),
                       (halo, halo + block_size - 1)),
                mode="edge").astype(jnp.float32)
    h1, w1 = H + 2 * halo - 2, W + 2 * halo - 2

    def sh(dy, dx):
        return g[dy:dy + h1, dx:dx + w1]

    dx = (sh(0, 2) + 2 * sh(1, 2) + sh(2, 2)
          - sh(0, 0) - 2 * sh(1, 0) - sh(2, 0))
    dy = (sh(2, 0) + 2 * sh(2, 1) + sh(2, 2)
          - sh(0, 0) - 2 * sh(0, 1) - sh(0, 2))
    ixx, iyy, ixy = dx * dx, dy * dy, dx * dy

    def box(a):
        out = jnp.zeros((H, W), jnp.float32)
        for by in range(block_size):
            for bx in range(block_size):
                out = out + a[by:by + H, bx:bx + W]
        return out

    sxx, syy, sxy = box(ixx), box(iyy), box(ixy)
    det = sxx * syy - sxy * sxy
    tr = sxx + syy
    return det - k * tr * tr


def normalize(x: jax.Array, alpha: float = 0.0, beta: float = 255.0) -> jax.Array:
    lo, hi = jnp.min(x), jnp.max(x)
    return (x - lo) / jnp.maximum(hi - lo, 1e-12) * (beta - alpha) + alpha


def convert_scale_abs(x: jax.Array, alpha: float = 1.0, beta: float = 0.0) -> jax.Array:
    return jnp.clip(jnp.abs(x * alpha + beta), 0.0, 255.0)


# --------------------------------------------------------------------------- #
# the unmodified "binary" (paper Fig. 4 flow)
# --------------------------------------------------------------------------- #
def corner_harris_demo(lib):
    """Returns the demo app over an interposable Library — the user's code."""

    def app(img):
        gray = lib.cvtColor(img)
        resp = lib.cornerHarris(gray)
        norm = lib.normalize(resp)
        return lib.convertScaleAbs(norm)

    app.__name__ = "cornerHarris_Demo"
    return app


# --------------------------------------------------------------------------- #
# database registration (cost providers = the synthesis-report analog)
# --------------------------------------------------------------------------- #
def _c_cvt(shapes, dtypes, params) -> NodeCost:
    h, w = shapes[0][:2]
    return elementwise_cost(h * w, flops_per_el=5, bytes_per_el=4, n_operands=4)


def _c_harris(shapes, dtypes, params) -> NodeCost:
    h, w = shapes[0][:2]
    return stencil_cost(h, w, 1, taps=6 * 2 + 4 * 3 + 8)   # sobel+box+response


def _c_norm(shapes, dtypes, params) -> NodeCost:
    h, w = shapes[0][:2]
    return elementwise_cost(h * w, flops_per_el=4, bytes_per_el=4, n_operands=3)


def _c_csa(shapes, dtypes, params) -> NodeCost:
    h, w = shapes[0][:2]
    return elementwise_cost(h * w, flops_per_el=4, bytes_per_el=4, n_operands=2)


def _fused_harris_vmem(w: int, n_parts: int, block_size: int = 2) -> int:
    """Resident bytes of the fused row-block kernel (rb=8 slab + halos).

    Mirrors ``kernels.harris.harris_fused``: an (rb + 2*halo)-row slab of
    the padded width for the RGB load (3 planes) + the gray scratch +
    ~6 stencil temporaries, plus one response/epilogue tile per fused part
    beyond the first; halo grows with the box ``block_size``.
    """
    rb = 8
    halo = 1 + block_size // 2
    wp = w + 2 * halo + block_size - 1
    bufs = 3 + 1 + 6 + (n_parts - 1)
    return (rb + 2 * halo) * wp * 4 * bufs


def _c_fused_pair(shapes, dtypes, params) -> NodeCost:
    """Synthesis-report analog for the fused cvtColor+cornerHarris module:
    the gray intermediate stays in VMEM, its HBM write+read disappears."""
    h, w = shapes[0][:2]
    bs = (params or {}).get("block_size", 2)
    fe = fused_cost([_c_cvt(shapes, dtypes, params),
                     _c_harris([(h, w)], dtypes, params)],
                    intermediate_bytes=4 * h * w,
                    vmem_required=_fused_harris_vmem(w, 2, bs))
    return fe.cost


def _c_fused_mega(shapes, dtypes, params) -> NodeCost:
    h, w = shapes[0][:2]
    bs = (params or {}).get("block_size", 2)
    fe = fused_cost([_c_cvt(shapes, dtypes, params),
                     _c_harris([(h, w)], dtypes, params),
                     _c_csa([(h, w)], dtypes, params)],
                    intermediate_bytes=2 * (4 * h * w),   # gray + response
                    vmem_required=_fused_harris_vmem(w, 3, bs))
    return fe.cost


def make_harris_db(with_hw: bool = True) -> ModuleDatabase:
    """Build the module database for the case study.

    ``with_hw`` registers the Pallas modules for the three functions the
    paper had HLS modules for; ``normalize`` never gets one (paper Table I).
    """
    db = ModuleDatabase("harris")
    db.register("cvtColor", software=cvt_color, cost_hw=_c_cvt, cost_sw=_c_cvt)
    db.register("cornerHarris", software=corner_harris, cost_hw=_c_harris,
                cost_sw=_c_harris)
    db.register("normalize", software=normalize, cost_sw=_c_norm)  # sw-only!
    db.register("convertScaleAbs", software=convert_scale_abs, cost_hw=_c_csa,
                cost_sw=_c_csa)
    if with_hw:
        try:
            from repro.kernels import harris as hk
            db.add_accelerated("cvtColor", hk.cvt_color)
            db.add_accelerated("cornerHarris", hk.corner_harris)
            db.add_accelerated("convertScaleAbs", hk.convert_scale_abs)
            # dedicated fused modules (single-pass mega-kernels): resolved
            # by the backend for fused nodes when the cost model accepts
            # the fusion.  In the demo chain `normalize` (sw-only) sits
            # between cornerHarris and convertScaleAbs, so the fusable run
            # is the pair; the 3-op mega-kernel serves normalize-free
            # variants of the chain.
            db.register_fused(("cvtColor", "cornerHarris"),
                              hk.harris_fused_pair, cost_hw=_c_fused_pair)
            db.register_fused(("cvtColor", "cornerHarris",
                               "convertScaleAbs"),
                              hk.harris_fused, cost_hw=_c_fused_mega)
        except ImportError:
            pass
    return db
