"""Architecture configs — the selectable ``--arch`` model space.

One frozen dataclass describes every assigned architecture; per-layer
heterogeneity (gemma3's 5:1 local:global attention, llama-vision's
cross-attn layers) is encoded as data so the layer stack stays scannable.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import numpy as np


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 → d_model // n_heads

    # attention pattern
    window: int = 0                # 0 → full attention; else sliding window
    global_every: int = 0          # gemma3: every k-th layer is global
    rope_theta: float = 1e4
    rope_theta_global: float = 0.0  # 0 → same as rope_theta

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    hybrid: bool = False           # hymba: parallel attn + ssm heads
    rwkv: bool = False             # rwkv6: attention-free token mixing
    conv_kernel: int = 4

    # VLM (cross-attn image layers, stub frontend per task spec)
    cross_attn_every: int = 0      # every k-th layer is a cross-attn layer
    n_img_tokens: int = 1024

    # audio (decoder over precomputed EnCodec frame embeddings, stub frontend)
    embeds_in: bool = False        # model input is embeddings, not token ids

    dtype: str = "bfloat16"
    vocab_pad_to: int = 256
    tie_embeddings: bool = True

    # -- derived ------------------------------------------------------------- #
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return round_up(self.vocab, self.vocab_pad_to)

    @property
    def is_global_layer(self) -> np.ndarray:
        """Per-layer bool: full ("global") attention vs sliding window."""
        if self.global_every <= 0:
            return np.ones(self.n_layers, bool) if self.window == 0 \
                else np.zeros(self.n_layers, bool)
        idx = np.arange(self.n_layers)
        return (idx % self.global_every) == (self.global_every - 1)

    @property
    def layer_windows(self) -> np.ndarray:
        """Per-layer attention window (0 = unbounded), scannable as data."""
        w = np.full(self.n_layers, self.window or 0, dtype=np.int32)
        w[self.is_global_layer] = 0
        return w

    @property
    def layer_thetas(self) -> np.ndarray:
        th = np.full(self.n_layers, self.rope_theta, dtype=np.float32)
        if self.rope_theta_global:
            th[self.is_global_layer] = self.rope_theta_global
        return th

    @property
    def is_cross_layer(self) -> np.ndarray:
        if self.cross_attn_every <= 0:
            return np.zeros(self.n_layers, bool)
        idx = np.arange(self.n_layers)
        return (idx % self.cross_attn_every) == (self.cross_attn_every - 1)

    @property
    def n_params(self) -> float:
        """Total parameter count (for MODEL_FLOPS = 6·N·D)."""
        return _count_params(self, active_only=False)

    @property
    def n_params_active(self) -> float:
        """Active parameters per token (MoE: top_k experts only)."""
        return _count_params(self, active_only=True)

    def reduced(self, **overrides: Any) -> "ArchConfig":
        """A tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            d_ff=128, vocab=256, head_dim=16,
            n_img_tokens=16, dtype="float32",
        )
        if self.n_experts:
            small.update(n_experts=4, top_k=2)
        if self.window:
            small.update(window=8)
        if self.ssm_state:
            small.update(ssm_state=4)
        if self.cross_attn_every:
            small.update(cross_attn_every=2, n_layers=4)   # 2×(1 self + 1 cross)
        small.update(overrides)
        return replace(self, **small)


def _count_params(c: ArchConfig, active_only: bool) -> float:
    d, hd = c.d_model, c.hd
    emb = c.vocab_padded * d
    head = 0 if c.tie_embeddings else c.vocab_padded * d
    per_layer = 2 * d                                   # 2 rms norms
    if c.rwkv:
        per_layer += 6 * d * d                          # r,k,v,w,g,out projections
        per_layer += 2 * d                              # token-shift mixes (approx)
        per_layer += d * c.d_ff + c.d_ff * d + d * d    # channel mix (k,v,r)
    else:
        per_layer += d * c.n_heads * hd + 2 * d * c.n_kv_heads * hd \
            + c.n_heads * hd * d                        # q,k,v,o
        if c.hybrid:                                    # hymba ssm branch
            di = d
            per_layer += d * 2 * di + di * c.conv_kernel \
                + di * (2 * c.ssm_state + 2) + di * c.ssm_state + di * d
        if c.cross_attn_every:
            n_cross = int(c.is_cross_layer.sum())
            # cross-attn kv projections amortized over all layers
            per_layer += (2 * d * c.n_kv_heads * hd + d * c.n_heads * hd
                          + c.n_heads * hd * d) * n_cross / c.n_layers
        if c.n_experts:
            e = c.top_k if active_only else c.n_experts
            per_layer += e * (2 * d * c.d_ff + c.d_ff * d)   # swiglu experts
            per_layer += d * c.n_experts                      # router
        else:
            per_layer += 2 * d * c.d_ff + c.d_ff * d          # swiglu
    return emb + head + c.n_layers * per_layer + d               # final norm


# --------------------------------------------------------------------------- #
# Input shapes (assigned per task spec; same 4 for every LM arch)
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def needs_subquadratic(shape: ShapeConfig) -> bool:
    return shape.name == "long_500k"


def supports_shape(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k only runs for sub-quadratic archs (see DESIGN.md §5)."""
    if not needs_subquadratic(shape):
        return True, ""
    if cfg.rwkv or cfg.ssm_state or cfg.window:
        return True, ""
    return False, ("pure full-attention arch: 524k decode requires a full "
                   "KV cache the shape list excludes by construction "
                   "(DESIGN.md §5)")
