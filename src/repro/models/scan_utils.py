"""Scan helpers — time-chunked remat for long recurrences.

A plain ``lax.scan`` over T steps saves every per-step carry for the
backward pass (O(T) state memory).  ``chunked_scan`` reshapes T into
(T/c, c) and checkpoints each chunk: saved state drops to O(T/c + c)
(sqrt-remat), which is what makes 4k-32k-step SSM/RWKV recurrences
trainable without blowing HBM.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp


def chunked_scan(body: Callable, carry: Any, xs: Any, *, chunk: int = 0,
                 remat: bool = True) -> tuple[Any, Any]:
    """Drop-in for ``lax.scan(body, carry, xs)`` with chunked remat.

    ``xs`` leaves are [T, ...]; ``chunk`` must divide T (0 → plain scan).
    """
    T = jax.tree.leaves(xs)[0].shape[0]
    if not chunk or T % chunk or T <= chunk:
        return jax.lax.scan(body, carry, xs)
    n = T // chunk
    xs_c = jax.tree.map(lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    def outer(c, xc):
        c, ys = jax.lax.scan(body, c, xc)
        return c, ys

    if remat:
        outer = jax.checkpoint(outer)
    carry, ys = jax.lax.scan(outer, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((T,) + a.shape[2:]), ys)
    return carry, ys
