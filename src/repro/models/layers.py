"""Transformer building blocks — functional, param-dict style (no flax).

Conventions:
  * params are nested dicts of jax Arrays; layer stacks have leading dim L
  * compute dtype = config dtype (bf16 on TPU); softmax/norms accumulate f32
  * attention is GQA with an optional sliding window passed *as data* so a
    heterogeneous local/global stack (gemma3) remains a uniform lax.scan
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


# --------------------------------------------------------------------------- #
# init helpers
# --------------------------------------------------------------------------- #
def _dense_init(key, shape, dtype, scale: float | None = None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------------- #
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------- #
# RoPE (theta passed as data → per-layer theta inside scan)
# --------------------------------------------------------------------------- #
def apply_rope(x: jax.Array, pos: jax.Array, theta) -> jax.Array:
    """x: [..., T, n, hd]; pos: [..., T] absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freq = jnp.exp(-jnp.log(jnp.asarray(theta, jnp.float32))
                   * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freq          # [..., T, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1.astype(jnp.float32) * cos - x2.astype(jnp.float32) * sin
    y2 = x2.astype(jnp.float32) * cos + x1.astype(jnp.float32) * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------- #
# GQA attention (full / sliding-window / cross), optional KV cache
# --------------------------------------------------------------------------- #
def attention_init(key, d: int, n_heads: int, n_kv: int, hd: int, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": _dense_init(k1, (d, n_heads, hd), dtype),
        "wk": _dense_init(k2, (d, n_kv, hd), dtype),
        "wv": _dense_init(k3, (d, n_kv, hd), dtype),
        "wo": _dense_init(k4, (n_heads * hd, d), dtype),
    }


def expand_kv(kv: jax.Array, n_heads: int) -> jax.Array:
    """[B, M, KV, hd] → [B, M, H, hd]; q-head h uses kv-head h // (H/KV).

    Two lowerings with identical semantics, chosen by shardability:
    * KV divides the model axis → reshape-broadcast: the merged KV·G dim
      inherits KV's sharding, so a model-sharded KV cache expands with
      ZERO communication (a take() here all-gathers the cache every
      decode step — observed as the collective-bound gemma3-27b decode).
    * otherwise (kv=8 on a 16-way axis) → head-index gather from the
      small replicated kv tensor, shardable on the output H axis.
    """
    B, M, KV, hd = kv.shape
    G = n_heads // KV
    if _ATTN_MESH is not None and "model" in _ATTN_MESH.axis_names \
            and KV % _ATTN_MESH.shape["model"] == 0:
        out = jnp.broadcast_to(kv[:, :, :, None], (B, M, KV, G, hd))
        return out.reshape(B, M, KV * G, hd)
    idx = jnp.arange(n_heads, dtype=jnp.int32) // G
    return jnp.take(kv, idx, axis=2)


def gqa_scores(q: jax.Array, k_exp: jax.Array) -> jax.Array:
    """q: [B, T, H, hd], k_exp: [B, M, H, hd] → scores [B, H, T, M] f32."""
    hd = q.shape[-1]
    return jnp.einsum("bthd,bmhd->bhtm", q, k_exp,
                      preferred_element_type=jnp.float32) / np.sqrt(hd)


def gqa_combine(probs: jax.Array, v_exp: jax.Array) -> jax.Array:
    """probs: [B, H, T, M], v_exp: [B, M, H, hd] → [B, T, H*hd]."""
    B, H, T, M = probs.shape
    hd = v_exp.shape[-1]
    out = jnp.einsum("bhtm,bmhd->bthd", probs, v_exp)
    return out.reshape(B, T, H * hd)


def attn_mask(q_pos: jax.Array, k_pos: jax.Array, window,
              causal: bool = True) -> jax.Array:
    """[T, M] bool. window as traced data: 0/negative → unbounded."""
    d = q_pos[:, None] - k_pos[None, :]
    m = (d >= 0) if causal else jnp.ones(d.shape, bool)
    w = jnp.asarray(window, jnp.int32)
    return m & jnp.where(w > 0, d < w, True)


Q_CHUNK = 1024      # query-block size for the memory-efficient path

# Head-parallel attention anchoring.  When a mesh is registered, q/k_exp/
# v_exp get constrained to [B→batch-axes, T, H→model, hd] so GSPMD runs
# Megatron-style head-parallel attention (each device: H/model heads × full
# kv length) instead of drifting to kv-seq sharding, which replicates all
# H heads per device and blows HBM.  Set by the launch layer at build time.
_ATTN_MESH = None


def set_attention_mesh(mesh) -> None:
    global _ATTN_MESH
    _ATTN_MESH = mesh


def _con_heads(x: jax.Array) -> jax.Array:
    """Constrain [B, T, H, hd] to batch×head sharding (divisibility-guarded)."""
    if _ATTN_MESH is None:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    mesh = _ATTN_MESH
    B, T, H, hd = x.shape
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    b_ax = baxes if baxes and B % nb == 0 else None
    if isinstance(b_ax, tuple) and len(b_ax) == 1:
        b_ax = b_ax[0]
    h_ax = "model" if "model" in mesh.axis_names and H % mesh.shape["model"] == 0 else None
    d_ax = None
    if h_ax is None and "model" in mesh.axis_names and hd % mesh.shape["model"] == 0:
        d_ax = "model"          # kv/odd-head fallback: shard head_dim
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, None, h_ax, d_ax)))


def _con_groups(x: jax.Array) -> jax.Array:
    """Constrain [G, Ng, d] routing groups to G→batch-axes (MoE dispatch)."""
    if _ATTN_MESH is None:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    mesh = _ATTN_MESH
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    if not baxes or x.shape[0] % nb:
        return x
    b_ax = baxes if len(baxes) > 1 else baxes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, None, None)))


def _con_experts(x: jax.Array) -> jax.Array:
    """Constrain [G, E, C, ...] expert buffers to E→model (EP compute).

    Without this anchor the expert FFN einsums drift to replicated-E
    (every model shard computes all experts — 16x redundant compute,
    observed as useful-ratio 0.05 in the baseline roofline).
    """
    if _ATTN_MESH is None:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    mesh = _ATTN_MESH
    if "model" not in mesh.axis_names or x.shape[1] % mesh.shape["model"]:
        return x
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    b_ax = baxes if baxes and x.shape[0] % nb == 0 else None
    if isinstance(b_ax, tuple) and len(b_ax) == 1:
        b_ax = b_ax[0]
    spec = [b_ax, "model"] + [None] * (x.ndim - 2)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def _con_ff(x: jax.Array) -> jax.Array:
    """Constrain [B, T, ..., ff] to ff→model (Megatron MLP hidden).

    Forces the wi matmul to keep ff sharded (gathering only the seq dim of
    the activation), and the wo matmul to contract the sharded ff into a
    reduce-scatter — instead of GSPMD gathering the full weight AND the
    full activation when seq- and ff-shardings conflict.
    """
    if _ATTN_MESH is None:
        return x
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    mesh = _ATTN_MESH
    if "model" not in mesh.axis_names or x.shape[-1] % mesh.shape["model"]:
        return x
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    b_ax = baxes if baxes and x.shape[0] % nb == 0 else None
    if isinstance(b_ax, tuple) and len(b_ax) == 1:
        b_ax = b_ax[0]
    spec = [b_ax] + [None] * (x.ndim - 2) + ["model"]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def _attend_chunked(q: jax.Array, k: jax.Array, v: jax.Array,
                    k_pos: jax.Array, window, causal: bool,
                    q_chunk: int) -> jax.Array:
    """Query-blocked attention: never materializes the full [T, M] probs.

    Scans over query blocks; each block computes a full-width f32 score
    slab [B, KV, G, qc, M], softmaxes and contracts it, then frees it.
    The scan body is checkpointed so backward recomputes one slab at a
    time — the pure-jnp analog of the Pallas flash kernel's tiling.
    """
    B, T, H, hd = q.shape
    nc = T // q_chunk
    q = _con_heads(q)
    qc = q.reshape(B, nc, q_chunk, H, hd).swapaxes(0, 1)   # [nc, B, qc, H, hd]
    k_exp, v_exp = _con_heads(expand_kv(k, H)), _con_heads(expand_kv(v, H))

    def body(_, inp):
        qi, i = inp
        q_pos = i * q_chunk + jnp.arange(q_chunk, dtype=jnp.int32)
        scores = gqa_scores(qi, k_exp)
        if causal:
            mask = attn_mask(q_pos, k_pos, window)
            scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return 0, gqa_combine(probs, v_exp)

    _, out = jax.lax.scan(jax.checkpoint(body), 0,
                          (qc, jnp.arange(nc, dtype=jnp.int32)))
    return out.swapaxes(0, 1).reshape(B, T, H * hd)


def attention(p: Params, x: jax.Array, pos: jax.Array, *,
              theta, window=0, kv_x: jax.Array | None = None,
              cache: Params | None = None, cache_pos=None) -> tuple[jax.Array, Params | None]:
    """General attention.

    * self-attn (train/prefill): kv_x=None, cache=None → causal (+window)
    * cross-attn: kv_x = image/frame states, no mask, no rope
    * decode: cache = {"k","v"} [B, M, KV, hd]; cache_pos = write index;
      x is [B, 1, d]; returns updated cache
    """
    B, T, dm = x.shape
    q = jnp.einsum("btd,dnh->btnh", x, p["wq"])
    if kv_x is None:
        k = jnp.einsum("btd,dnh->btnh", x, p["wk"])
        v = jnp.einsum("btd,dnh->btnh", x, p["wv"])
    else:
        k = jnp.einsum("bmd,dnh->bmnh", kv_x, p["wk"])
        v = jnp.einsum("bmd,dnh->bmnh", kv_x, p["wv"])

    new_cache = None
    if cache is not None:                       # decode: one new token
        q_pos = jnp.full((T,), cache_pos, jnp.int32) + jnp.arange(T, dtype=jnp.int32)
        q = apply_rope(q, q_pos[None, :], theta)
        k = apply_rope(k, q_pos[None, :], theta)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype),
                                                 cache_pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype),
                                                 cache_pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        M = ck.shape[1]
        H = q.shape[2]
        k_pos = jnp.arange(M, dtype=jnp.int32)
        mask = attn_mask(q_pos, k_pos, window)                   # [T, M]
        scores = gqa_scores(_con_heads(q), _con_heads(expand_kv(ck, H)))
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = gqa_combine(probs, _con_heads(expand_kv(cv, H)))
    elif kv_x is None:                          # train / prefill self-attn
        q_pos = jnp.arange(T, dtype=jnp.int32)
        q = apply_rope(q, q_pos[None, :], theta)
        k = apply_rope(k, q_pos[None, :], theta)
        if T >= 2 * Q_CHUNK and T % Q_CHUNK == 0:
            out = _attend_chunked(q, k, v, q_pos, window, True, Q_CHUNK)
        else:
            H = q.shape[2]
            mask = attn_mask(q_pos, q_pos, window)
            scores = gqa_scores(_con_heads(q), _con_heads(expand_kv(k, H)))
            scores = jnp.where(mask[None, None], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = gqa_combine(probs, _con_heads(expand_kv(v, H)))
    else:                                       # cross-attn (no rope/mask)
        if T >= 2 * Q_CHUNK and T % Q_CHUNK == 0:
            out = _attend_chunked(q, k, v,
                                  jnp.arange(k.shape[1], dtype=jnp.int32),
                                  0, False, Q_CHUNK)
        else:
            H = q.shape[2]
            scores = gqa_scores(_con_heads(q), _con_heads(expand_kv(k, H)))
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = gqa_combine(probs, _con_heads(expand_kv(v, H)))

    y = jnp.einsum("btf,fd->btd", out, p["wo"])
    return y, new_cache


# --------------------------------------------------------------------------- #
# SwiGLU MLP
# --------------------------------------------------------------------------- #
def mlp_init(key, d: int, ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {"wi": _dense_init(k1, (d, 2, ff), dtype),
            "wo": _dense_init(k2, (ff, d), dtype)}


def mlp(p: Params, x: jax.Array) -> jax.Array:
    gu = _con_ff(jnp.einsum("btd,dcf->btcf", x, p["wi"]))
    g, u = gu[:, :, 0], gu[:, :, 1]
    h = _con_ff(jax.nn.silu(g) * u)
    return jnp.einsum("btf,fd->btd", h, p["wo"])


# --------------------------------------------------------------------------- #
# Embedding / LM head
# --------------------------------------------------------------------------- #
def embed_init(key, vocab_padded: int, d: int, dtype) -> Params:
    return {"table": _dense_init(key, (vocab_padded, d), dtype, scale=1.0)}


def embed(p: Params, ids: jax.Array) -> jax.Array:
    return jnp.take(p["table"], ids, axis=0)


def lm_logits(p: Params, h: jax.Array, vocab: int) -> jax.Array:
    logits = jnp.einsum("btd,vd->btv", h, p["table"],
                        preferred_element_type=jnp.float32)
    return logits[..., :vocab]
