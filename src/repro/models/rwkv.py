"""RWKV-6 "Finch" block — attention-free token mixing with data-dependent decay.

Per head (hd=64), the time-mix recurrence over a matrix-valued state S:

    y_t = r_t · (S_{t-1} + (u ∘ k_t) ⊗ v_t)
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t

where the decay w_t = exp(-exp(wb + lora(x_t))) is *data-dependent* — the
RWKV-6 signature (arXiv:2404.05892).  Channel-mix is the squared-ReLU FFN.
Decode carries (S, token-shift) state; everything is a lax.scan over time.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import _dense_init

Params = Any
HEAD_DIM = 64


def rwkv_init(key, d: int, ff: int, dtype, lora_rank: int = 32) -> Params:
    ks = jax.random.split(key, 12)
    H = d // HEAD_DIM
    return {
        # time-mix
        "mu": jnp.zeros((5, d), jnp.float32),          # shift-mix for r,k,v,w,g
        "wr": _dense_init(ks[0], (d, d), dtype),
        "wk": _dense_init(ks[1], (d, d), dtype),
        "wv": _dense_init(ks[2], (d, d), dtype),
        "wg": _dense_init(ks[3], (d, d), dtype),
        "w_bias": jnp.zeros((d,), jnp.float32),
        "w_lora_a": _dense_init(ks[4], (d, lora_rank), dtype),
        "w_lora_b": _dense_init(ks[5], (lora_rank, d), dtype, scale=0.01),
        "u": jnp.zeros((H, HEAD_DIM), jnp.float32),    # bonus
        "ln_scale": jnp.ones((d,), jnp.float32),       # per-head group norm
        "wo": _dense_init(ks[6], (d, d), dtype),
        # channel-mix
        "mu_c": jnp.zeros((2, d), jnp.float32),
        "ck": _dense_init(ks[7], (d, ff), dtype),
        "cv": _dense_init(ks[8], (ff, d), dtype),
        "cr": _dense_init(ks[9], (d, d), dtype),
    }


def _shift(x: jax.Array, last: jax.Array | None) -> jax.Array:
    """Previous-token sequence shift; `last` is [B, d] carry for decode."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def time_mix(p: Params, x: jax.Array, S0: jax.Array,
             last: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """x: [B,T,d]; S0: [B,H,hd,hd] f32. Returns (y, S_T)."""
    B, T, d = x.shape
    H = d // HEAD_DIM
    xx = _shift(x, last)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (xx - x) * mu[i] for i in range(5))
    r = jnp.einsum("btd,de->bte", xr, p["wr"]).reshape(B, T, H, HEAD_DIM)
    k = jnp.einsum("btd,de->bte", xk, p["wk"]).reshape(B, T, H, HEAD_DIM)
    v = jnp.einsum("btd,de->bte", xv, p["wv"]).reshape(B, T, H, HEAD_DIM)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"]))
    # data-dependent decay (RWKV-6 lora)
    wlog = p["w_bias"] + jnp.einsum(
        "btd,dr,re->bte", xw.astype(jnp.float32),
        p["w_lora_a"].astype(jnp.float32), p["w_lora_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, T, H, HEAD_DIM)        # (0,1)

    u = p["u"]

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                                   # [B,H,hd] each
        kv = k_t[..., :, None] * v_t[..., None, :]                 # [B,H,hd,hd]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    from .scan_utils import chunked_scan
    S_T, ys = chunked_scan(step, S0, xs, chunk=256 if T % 256 == 0 else 0)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, d)                    # [B,T,d] f32
    # per-head group norm
    yh = y.reshape(B, T, H, HEAD_DIM)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + 1e-5)
    y = (yh.reshape(B, T, d) * p["ln_scale"]).astype(x.dtype) * g
    return jnp.einsum("btd,de->bte", y, p["wo"]), S_T


def channel_mix(p: Params, x: jax.Array, last: jax.Array | None) -> jax.Array:
    xx = _shift(x, last)
    mu = p["mu_c"].astype(x.dtype)
    xk = x + (xx - x) * mu[0]
    xr = x + (xx - x) * mu[1]
    k = jnp.einsum("btd,df->btf", xk, p["ck"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("btf,fd->btd", k, p["cv"])
    return jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["cr"])) * kv


def rwkv_block(p: Params, x: jax.Array, norm1, norm2,
               state: Params | None = None) -> tuple[jax.Array, Params]:
    """Full RWKV block: time-mix + channel-mix with residuals.

    ``state`` = {"S": [B,H,hd,hd], "tm_last": [B,d], "cm_last": [B,d]}.
    """
    from .layers import rmsnorm
    B, T, d = x.shape
    H = d // HEAD_DIM
    if state is None:
        S0, tm_last, cm_last = (
            jnp.zeros((B, H, HEAD_DIM, HEAD_DIM), jnp.float32), None, None)
    else:
        S0, tm_last, cm_last = state["S"], state["tm_last"], state["cm_last"]
    h1 = rmsnorm(norm1, x)
    y, S_T = time_mix(p, h1, S0, tm_last)
    x = x + y
    h2 = rmsnorm(norm2, x)
    x = x + channel_mix(p, h2, cm_last)
    new_state = {"S": S_T, "tm_last": h1[:, -1], "cm_last": h2[:, -1]}
    return x, new_state


def rwkv_init_state(batch: int, d: int, dtype) -> Params:
    H = d // HEAD_DIM
    return {"S": jnp.zeros((batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
            "tm_last": jnp.zeros((batch, d), dtype),
            "cm_last": jnp.zeros((batch, d), dtype)}
