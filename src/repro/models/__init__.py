"""Model zoo — unified LM stack + the paper's Harris case-study app."""
from .config import SHAPES, ArchConfig, ShapeConfig, supports_shape
from .transformer import LM

__all__ = ["LM", "ArchConfig", "ShapeConfig", "SHAPES", "supports_shape"]
