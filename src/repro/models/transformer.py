"""Unified decoder-only LM covering all assigned architecture families.

Families (selected by ArchConfig fields):
  dense   — GQA attention + SwiGLU (mistral-large, deepseek, gemma3*, musicgen)
  moe     — GQA attention + top-k expert FFN (qwen3-moe, moonshot)
  hybrid  — parallel GQA + selective-SSM heads (hymba)
  ssm     — RWKV-6 attention-free blocks (rwkv6)
  vlm     — grouped stack: (k self layers + 1 cross-attn layer) × groups
            (llama-3.2-vision; image patch embeddings come from the stub
            frontend per task spec)
  audio   — dense backbone over precomputed EnCodec frame embeddings
            (musicgen; stub frontend)

The layer stack is a ``lax.scan`` over stacked params — per-layer
heterogeneity (sliding window size, rope theta) rides along as scan data, so
gemma3's 5:1 local:global pattern costs no extra HLO.  Training uses
``jax.checkpoint`` per layer (remat) and a chunked cross-entropy that never
materializes the full [B, S, V] logits.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .config import ArchConfig
from .layers import (attention, attention_init, embed, embed_init, lm_logits,
                     mlp, mlp_init, rmsnorm, rmsnorm_init)
from .moe import moe_apply, moe_init
from .rwkv import rwkv_block, rwkv_init, rwkv_init_state
from .ssm import ssm_apply, ssm_init, ssm_init_state

Params = Any


# =========================================================================== #
# Per-layer block
# =========================================================================== #
def _block_init(cfg: ArchConfig, key, cross: bool = False) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p: dict = {"ln1": rmsnorm_init(cfg.d_model, dtype),
               "ln2": rmsnorm_init(cfg.d_model, dtype)}
    if cfg.rwkv:
        p["rwkv"] = rwkv_init(ks[0], cfg.d_model, cfg.d_ff, dtype)
        return p
    p["attn"] = attention_init(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.hd, dtype)
    if cfg.hybrid:
        p["ssm"] = ssm_init(ks[1], cfg.d_model, cfg.ssm_state,
                            cfg.conv_kernel, dtype)
    if cfg.n_experts and not cross:
        p["moe"] = moe_init(ks[2], cfg.d_model, cfg.d_ff, cfg.n_experts, dtype)
    else:
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def _zero_aux() -> dict:
    return {"load_balance_loss": jnp.zeros((), jnp.float32),
            "router_z_loss": jnp.zeros((), jnp.float32),
            "dropped_frac": jnp.zeros((), jnp.float32)}


def _block_apply(cfg: ArchConfig, p: Params, x: jax.Array, *,
                 window, theta, img_kv: Params | None = None,
                 cache: Params | None = None, cache_pos=None,
                 is_cross: bool = False) -> tuple[jax.Array, Params | None, dict]:
    """One block. Returns (x, new_cache, aux)."""
    aux = _zero_aux()
    if cfg.rwkv:
        x, new_state = rwkv_block(p["rwkv"], x, p["ln1"], p["ln2"],
                                  state=cache)
        return x, new_state, aux

    h = rmsnorm(p["ln1"], x)
    new_cache: dict = {}
    if is_cross:
        # cross-attn layer: kv from image states (dict = precomputed K/V
        # cached at prefill; array = raw image embeddings)
        if isinstance(img_kv, dict):
            a, _ = _cross_from_cache(p, h, img_kv)
        else:
            a, _ = attention(p["attn"], h, None, theta=theta, kv_x=img_kv)
    else:
        if cache is not None:
            a, kvc = attention(p["attn"], h, None, theta=theta, window=window,
                               cache={"k": cache["k"], "v": cache["v"]},
                               cache_pos=cache_pos)
            new_cache.update(kvc)
        else:
            a, _ = attention(p["attn"], h, None, theta=theta, window=window)
    if cfg.hybrid:
        s_state = cache.get("ssm") if cache else None
        s, s_new = ssm_apply(p["ssm"], h, state=s_state)
        a = (a + s) * 0.5
        new_cache["ssm"] = s_new
    x = x + a

    h2 = rmsnorm(p["ln2"], x)
    if "moe" in p and not is_cross:
        y, aux = moe_apply(p["moe"], h2, cfg.top_k, cfg.moe_capacity_factor)
        aux = {**_zero_aux(), **aux}
    else:
        y = mlp(p["mlp"], h2)
    x = x + y
    if cache is not None and not is_cross:
        return x, new_cache, aux
    return x, (new_cache or None), aux


def _cross_from_cache(p: Params, h: jax.Array, img_kv: Params):
    """Cross-attention against precomputed image K/V."""
    from .layers import expand_kv, gqa_combine, gqa_scores
    q = jnp.einsum("btd,dnh->btnh", h, p["attn"]["wq"])
    H = q.shape[2]
    scores = gqa_scores(q, expand_kv(img_kv["ck"], H))
    probs = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    out = gqa_combine(probs, expand_kv(img_kv["cv"], H))
    return jnp.einsum("btf,fd->btd", out, p["attn"]["wo"]), None


# =========================================================================== #
# The model
# =========================================================================== #
class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # -- params -------------------------------------------------------------- #
    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        k_emb, k_layers, k_cross = jax.random.split(key, 3)
        params: dict = {
            "embed": embed_init(k_emb, cfg.vocab_padded, cfg.d_model, dtype),
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
        if cfg.cross_attn_every:
            n_groups, per_group = self._vlm_groups()
            self_keys = jax.random.split(k_layers, n_groups * per_group)
            params["layers"] = jax.vmap(
                lambda k: jax.vmap(lambda kk: _block_init(cfg, kk))(k))(
                self_keys.reshape((n_groups, per_group) + self_keys.shape[1:]))
            cross_keys = jax.random.split(k_cross, n_groups)
            params["cross"] = jax.vmap(
                lambda k: _block_init(cfg, k, cross=True))(cross_keys)
        else:
            keys = jax.random.split(k_layers, cfg.n_layers)
            params["layers"] = jax.vmap(lambda k: _block_init(cfg, k))(keys)
        return params

    def _vlm_groups(self) -> tuple[int, int]:
        cfg = self.cfg
        per = cfg.cross_attn_every
        if cfg.n_layers % per:
            raise ValueError(f"n_layers {cfg.n_layers} not divisible by "
                             f"cross_attn_every {per}")
        return cfg.n_layers // per, per - 1   # (groups, self layers per group)

    def _layer_meta(self):
        cfg = self.cfg
        return (jnp.asarray(cfg.layer_windows),
                jnp.asarray(cfg.layer_thetas))

    # -- full-sequence forward (train / prefill-as-forward) ------------------- #
    def apply(self, params: Params, ids: jax.Array | None = None, *,
              embeds: jax.Array | None = None,
              img_embeds: jax.Array | None = None,
              remat: bool = True,
              act_constraint=None,
              param_constraint=None,
              scan_chunks: int = 0,
              unroll: bool = False) -> tuple[jax.Array, dict]:
        """→ (hidden [B,S,d], aux). Use :meth:`loss` / :meth:`logits` after.

        ``act_constraint``: optional fn applied to the layer carry (e.g.
        ``with_sharding_constraint`` for sequence-parallel activations).
        ``scan_chunks``: nested-remat scan — outer scan of L/c checkpointed
        chunks, each inner-scanning c layers, bounding saved activations to
        ~(L/c + c) instead of L (the classic sqrt-remat trade).
        """
        cfg = self.cfg
        con = act_constraint or (lambda h: h)
        pcon = param_constraint or (lambda p: p)
        x = embeds if cfg.embeds_in else embed(params["embed"], ids)
        x = con(x.astype(jnp.dtype(cfg.dtype)))

        if cfg.cross_attn_every:
            return self._apply_vlm(params, x, img_embeds, remat, con, pcon,
                                   unroll=unroll)

        windows, thetas = self._layer_meta()

        def body(carry, inp):
            h, aux = carry
            lp, w, th = inp
            # re-anchor the sliced layer weights (keeps the FSDP all-gather
            # inside the loop instead of a whole-model hoisted gather)
            h, _, a = _block_apply(cfg, pcon(lp), h, window=w, theta=th)
            return (con(h), jax.tree.map(jnp.add, aux, a)), None

        xs = (params["layers"], windows, thetas)
        if scan_chunks and cfg.n_layers % scan_chunks == 0:
            c = scan_chunks
            xs = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers // c, c) + a.shape[1:]), xs)
            # two-level remat: outer chunk AND per-layer body are both
            # checkpointed — saved state ~(L/c + c) boundaries, transients
            # bounded by one layer (costs one extra fwd recompute).
            inner = jax.checkpoint(body) if remat else body

            def chunk_body(carry, chunk):
                out, _ = jax.lax.scan(inner, carry, chunk)
                return out, None

            outer = jax.checkpoint(chunk_body) if remat else chunk_body
            (x, aux), _ = jax.lax.scan(outer, (x, _zero_aux()), xs)
        else:
            if remat:
                body = jax.checkpoint(body)
            (x, aux), _ = jax.lax.scan(body, (x, _zero_aux()), xs,
                                       unroll=cfg.n_layers if unroll else 1)
        x = rmsnorm(params["final_norm"], x)
        return x, aux

    def _apply_vlm(self, params, x, img_embeds, remat, con=lambda h: h,
                   pcon=lambda p: p, unroll: bool = False):
        cfg = self.cfg
        n_groups, per_group = self._vlm_groups()
        keep = ~cfg.is_cross_layer
        w_self = jnp.asarray(cfg.layer_windows[keep].reshape(n_groups, per_group))
        t_self = jnp.asarray(cfg.layer_thetas[keep].reshape(n_groups, per_group))

        def group(carry, inp):
            h, aux = carry
            sp, cp, ws, ts = inp

            def one(c, i):
                hh, ax = c
                lp, w, th = i
                hh, _, a = _block_apply(cfg, pcon(lp), hh, window=w, theta=th)
                return (con(hh), jax.tree.map(jnp.add, ax, a)), None

            (h, aux), _ = jax.lax.scan(one, (h, aux), (sp, ws, ts))
            h, _, a = _block_apply(cfg, pcon(cp), h, window=0,
                                   theta=cfg.rope_theta,
                                   img_kv=img_embeds, is_cross=True)
            return (con(h), jax.tree.map(jnp.add, aux, a)), None

        if remat:
            group = jax.checkpoint(group)
        (x, aux), _ = jax.lax.scan(
            group, (x, _zero_aux()),
            (params["layers"], params["cross"], w_self, t_self),
            unroll=n_groups if unroll else 1)
        x = rmsnorm(params["final_norm"], x)
        return x, aux

    # -- chunked LM loss (never materializes [B,S,V]) -------------------------- #
    def loss(self, params: Params, hidden: jax.Array, targets: jax.Array,
             mask: jax.Array | None = None, chunk: int = 512) -> jax.Array:
        cfg = self.cfg
        B, S, d = hidden.shape
        chunk = min(chunk, S)
        n = S // chunk
        hs = hidden[:, :n * chunk].reshape(B, n, chunk, d).swapaxes(0, 1)
        ts = targets[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
        ms = (mask[:, :n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
              if mask is not None else jnp.ones_like(ts, jnp.float32))
        table = params["embed"]["table"]

        def body(carry, inp):
            h, t, m = inp
            logits = jnp.einsum("bcd,vd->bcv", h, table,
                                preferred_element_type=jnp.float32)
            logits = logits[..., :cfg.vocab]
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
            nll = (lse - gold) * m
            return (carry[0] + nll.sum(), carry[1] + m.sum()), None

        (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                     (hs, ts, ms))
        return tot / jnp.maximum(cnt, 1.0)

    def logits(self, params: Params, hidden: jax.Array) -> jax.Array:
        return lm_logits(params["embed"], hidden, self.cfg.vocab)

    # -- KV cache / serving ----------------------------------------------------- #
    def init_cache(self, batch: int, cache_len: int) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        if cfg.rwkv:
            per = rwkv_init_state(batch, cfg.d_model, dtype)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(),
                per)
        per = {"k": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype),
               "v": jnp.zeros((batch, cache_len, cfg.n_kv_heads, cfg.hd), dtype)}
        if cfg.hybrid:
            per["ssm"] = ssm_init_state(batch, cfg.d_model, cfg.ssm_state,
                                        cfg.conv_kernel, dtype)
        if cfg.cross_attn_every:
            n_groups, per_group = self._vlm_groups()
            kv = jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (n_groups, per_group) + a.shape).copy(), per)
            cross = {
                "ck": jnp.zeros((n_groups, batch, cfg.n_img_tokens,
                                 cfg.n_kv_heads, cfg.hd), dtype),
                "cv": jnp.zeros((n_groups, batch, cfg.n_img_tokens,
                                 cfg.n_kv_heads, cfg.hd), dtype),
            }
            return {"self": kv, "cross": cross}
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), per)

    def prefill(self, params: Params, ids: jax.Array | None, cache: Params, *,
                embeds: jax.Array | None = None,
                img_embeds: jax.Array | None = None
                ) -> tuple[jax.Array, Params]:
        """Fill the cache with the prompt; returns (last-token hidden, cache)."""
        h, cache = self._forward_cached(params, ids, cache, 0, embeds=embeds,
                                        img_embeds=img_embeds)
        return h[:, -1:], cache

    def decode_step(self, params: Params, ids_step: jax.Array | None,
                    cache: Params, pos, *,
                    embeds: jax.Array | None = None,
                    param_constraint=None,
                    unroll: bool = False) -> tuple[jax.Array, Params]:
        """One token for every sequence. pos: current cache length (scalar)."""
        h, cache = self._forward_cached(params, ids_step, cache, pos,
                                        embeds=embeds, unroll=unroll,
                                        param_constraint=param_constraint)
        return self.logits(params, h), cache

    def _forward_cached(self, params, ids, cache, pos, *, embeds=None,
                        img_embeds=None, unroll: bool = False,
                        param_constraint=None):
        cfg = self.cfg
        pcon = param_constraint or (lambda p: p)
        x = embeds if cfg.embeds_in else embed(params["embed"], ids)
        x = x.astype(jnp.dtype(cfg.dtype))
        pos = jnp.asarray(pos, jnp.int32)

        if cfg.cross_attn_every:
            return self._forward_cached_vlm(params, x, cache, pos, img_embeds)
        u = cfg.n_layers if unroll else 1
        if cfg.rwkv:
            def body(h, inp):
                lp, st = inp
                h, new_st, _ = _block_apply(cfg, pcon(lp), h, window=0,
                                            theta=0.0, cache=st)
                return h, new_st
            x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                        unroll=u)
            x = rmsnorm(params["final_norm"], x)
            return x, new_cache

        windows, thetas = self._layer_meta()

        def body(h, inp):
            lp, st, w, th = inp
            h, new_st, _ = _block_apply(cfg, pcon(lp), h, window=w, theta=th,
                                        cache=st, cache_pos=pos)
            return h, new_st

        x, new_cache = jax.lax.scan(body, x,
                                    (params["layers"], cache, windows, thetas),
                                    unroll=u)
        x = rmsnorm(params["final_norm"], x)
        return x, new_cache

    def _forward_cached_vlm(self, params, x, cache, pos, img_embeds):
        cfg = self.cfg
        n_groups, per_group = self._vlm_groups()
        keep = ~cfg.is_cross_layer
        w_self = jnp.asarray(cfg.layer_windows[keep].reshape(n_groups, per_group))
        t_self = jnp.asarray(cfg.layer_thetas[keep].reshape(n_groups, per_group))

        # cross K/V: computed from image embeddings at prefill (img_embeds
        # given), reused from the cache at decode (img_embeds=None)
        if img_embeds is not None:
            def cross_kv(cp):
                k = jnp.einsum("bmd,dnh->bmnh", img_embeds, cp["attn"]["wk"])
                v = jnp.einsum("bmd,dnh->bmnh", img_embeds, cp["attn"]["wv"])
                return {"ck": k, "cv": v}
            cache = dict(cache)
            cache["cross"] = jax.vmap(cross_kv)(params["cross"])

        def group(h, inp):
            sp, cp, st, ckv, ws, ts = inp

            def one(hh, i):
                lp, s1, w, th = i
                hh, ns, _ = _block_apply(cfg, lp, hh, window=w, theta=th,
                                         cache=s1, cache_pos=pos)
                return hh, ns

            h, new_st = jax.lax.scan(one, h, (sp, st, ws, ts))
            h, _, _ = _block_apply(cfg, cp, h, window=0, theta=cfg.rope_theta,
                                   img_kv=ckv, is_cross=True)
            return h, new_st

        x, new_self = jax.lax.scan(
            group, x, (params["layers"], params["cross"], cache["self"],
                       cache["cross"], w_self, t_self))
        x = rmsnorm(params["final_norm"], x)
        return x, {"self": new_self, "cross": cache["cross"]}
