"""Selective SSM (Mamba-style) branch — used by the Hymba hybrid block.

Continuous-time selective state space, discretized per token:

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t          (state: [di, N])
    y_t = C_t . h_t + D * x_t

with input-dependent dt/B/C ("selective").  The sequential form is a
``lax.scan`` over time; decode carries (conv_state, ssm_state) explicitly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import _dense_init

Params = Any


def ssm_init(key, d: int, state: int, conv_k: int, dtype) -> Params:
    ks = jax.random.split(key, 6)
    di = d                                  # inner dim = d (heads split in hymba)
    return {
        "in_proj": _dense_init(ks[0], (d, 2, di), dtype),
        "conv": _dense_init(ks[1], (conv_k, di), dtype, scale=conv_k ** -0.5),
        "w_dt": _dense_init(ks[2], (di, di), dtype, scale=di ** -0.5),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "w_bc": _dense_init(ks[3], (di, 2, state), dtype),
        "A_log": jnp.zeros((di, state), jnp.float32),     # A = -exp(A_log) ≤ -1
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 init_state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv1d. x: [B, T, di], w: [K, di]."""
    K = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out


def _ssm_core(p: Params, xc: jax.Array, h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """xc: [B, T, di] (post-conv, pre-activation). Returns (y, h_T)."""
    xc = jax.nn.silu(xc)
    dt = jax.nn.softplus(jnp.einsum("btd,de->bte", xc, p["w_dt"])
                         .astype(jnp.float32) + p["dt_bias"])     # [B,T,di]
    bc = jnp.einsum("btd,dcn->btcn", xc, p["w_bc"]).astype(jnp.float32)
    Bt, Ct = bc[:, :, 0], bc[:, :, 1]                              # [B,T,N]
    A = -jnp.exp(p["A_log"])                                       # [di,N]

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                                  # [B,di],[B,di],[B,N],[B,N]
        dA = jnp.exp(dt_t[..., None] * A)                          # [B,di,N]
        dBx = dt_t[..., None] * b_t[:, None, :] * x_t[..., None].astype(jnp.float32)
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bt, 1, 0), jnp.moveaxis(Ct, 1, 0))
    from .scan_utils import chunked_scan
    T = xc.shape[1]
    hT, ys = chunked_scan(step, h0, xs, chunk=256 if T % 256 == 0 else 0)
    y = jnp.moveaxis(ys, 0, 1) + p["D"] * xc.astype(jnp.float32)   # [B,T,di]
    return y, hT


def ssm_apply(p: Params, x: jax.Array,
              state: Params | None = None) -> tuple[jax.Array, Params]:
    """Full-sequence (train/prefill). x: [B,T,d] → (y [B,T,d], state)."""
    B, T, d = x.shape
    N = p["A_log"].shape[1]
    xz = jnp.einsum("btd,dci->btci", x, p["in_proj"])
    xi, z = xz[:, :, 0], xz[:, :, 1]
    conv_in = state["conv"] if state is not None else None
    xc = _causal_conv(xi, p["conv"], conv_in)
    h0 = state["h"] if state is not None else jnp.zeros((B, d, N), jnp.float32)
    y, hT = _ssm_core(p, xc, h0)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("btd,de->bte", y, p["out_proj"])
    K = p["conv"].shape[0]
    tail = xi[:, -(K - 1):] if T >= K - 1 else jnp.concatenate(
        [state["conv"][:, T:], xi], axis=1) if state is not None else None
    new_state = {"h": hT, "conv": tail if tail is not None
                 else jnp.zeros((B, K - 1, d), x.dtype)}
    return out, new_state


def ssm_init_state(batch: int, d: int, state: int, conv_k: int, dtype) -> Params:
    return {"h": jnp.zeros((batch, d, state), jnp.float32),
            "conv": jnp.zeros((batch, conv_k - 1, d), dtype)}
