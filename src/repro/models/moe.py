"""Mixture-of-Experts FFN — sort-based capacity dispatch, EP-shardable.

Dispatch avoids the GShard one-hot einsum (quadratic in tokens): token→expert
assignments are stably sorted, each token gets its position inside its
expert's segment via a searchsorted prefix, tokens beyond the expert's
capacity are dropped (overflow slot), and expert FFNs run as one batched
einsum over the [E, C, d] buffer.  Expert-major weights shard their leading
E axis over the "model" mesh axis (expert parallelism).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .layers import _dense_init

Params = Any


def moe_init(key, d: int, ff: int, n_experts: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "router": _dense_init(k1, (d, n_experts), jnp.float32),
        "wi": _dense_init(k2, (n_experts, d, 2, ff), dtype),
        "wo": _dense_init(k3, (n_experts, ff, d), dtype),
    }


def _grouped_dispatch(p: Params, xg: jax.Array, top_k: int, C: int
                      ) -> tuple[jax.Array, dict]:
    """Sort-based dispatch+combine, batched over groups. xg: [G, Ng, d].

    The group dim G is kept *explicit* (no vmap) so sharding anchors reach
    the expert buffers: G shards over the batch axes (DP-local routing) and
    the expert dim shards over "model" (EP) — see `_con_experts`.
    """
    from .layers import _con_experts, _con_groups
    G, N, d = xg.shape
    E = p["router"].shape[1]
    Nk = N * top_k
    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)                   # [G, N, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    flat_e = idx.reshape(G, Nk)
    flat_t = jnp.tile(jnp.repeat(jnp.arange(N, dtype=jnp.int32), top_k),
                      (G, 1))
    flat_g = gate.reshape(G, Nk)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    st = jnp.take_along_axis(flat_t, order, axis=-1)
    sg = jnp.take_along_axis(flat_g, order, axis=-1)
    seg_start = jax.vmap(
        lambda s: jnp.searchsorted(s, jnp.arange(E, dtype=s.dtype)))(se)
    pos = (jnp.arange(Nk, dtype=jnp.int32)[None]
           - jnp.take_along_axis(seg_start, se, axis=-1).astype(jnp.int32))
    keep = pos < C
    dest = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)

    gi = jnp.arange(G, dtype=jnp.int32)[:, None]
    xs = jnp.take_along_axis(xg, st[..., None], axis=1)        # [G, Nk, d]
    buf = jnp.zeros((G, E * C + 1, d), xg.dtype).at[gi, dest].set(xs)
    eb = buf[:, :E * C].reshape(G, E, C, d)   # E-replicated per group shard

    # EP: anchor the einsum OUTPUTS to E→model — each model shard computes
    # only its experts (reads a local slice of the replicated buffer); the
    # inputs stay un-anchored so no scatter→EP reshard is forced.
    gu = _con_experts(jnp.einsum("gecd,edkf->geckf", eb, p["wi"]))
    h = jax.nn.silu(gu[:, :, :, 0]) * gu[:, :, :, 1]
    out = _con_experts(jnp.einsum("gecf,efd->gecd", h, p["wo"]))

    # combine in SLOT order (no cross-shard gather): invert the dispatch
    # map with tiny int scatters, then scatter-add the E-sharded expert
    # rows into the token buffer (GSPMD: local partial sums + all-reduce).
    slot_t = jnp.zeros((G, E * C + 1), jnp.int32).at[gi, dest].set(st)
    slot_g = jnp.zeros((G, E * C + 1), xg.dtype).at[gi, dest].set(
        (sg * keep).astype(xg.dtype))
    contrib = out.reshape(G, E * C, d) * slot_g[:, :E * C, None]
    y = jnp.zeros((G, N, d), xg.dtype).at[gi, slot_t[:, :E * C]].add(contrib)
    y = _con_groups(y)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = {
        "load_balance_loss": E * jnp.sum(me * ce),
        "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux


def _einsum_dispatch(p: Params, xg: jax.Array, top_k: int, C: int
                     ) -> tuple[jax.Array, dict]:
    """GShard-style all-einsum dispatch/combine. xg: [G, Ng, d], many small
    groups (Ng ≈ 512).

    No data-dependent scatter/gather anywhere: position-in-expert comes
    from per-slot cumsums, dispatch/combine are one-hot mask einsums, so
    GSPMD partitions every op as a blocked einsum — G over the batch axes,
    E over "model" (EP) — with zero redundant compute.  Dispatch-mask
    flops ≈ E·C·d/(k·3·d·f_exp) ≈ 14% of expert flops for qwen3-moe.
    """
    from .layers import _con_experts
    G, N, d = xg.shape
    E = p["router"].shape[1]
    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)                    # [G, N, k]
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    counts = jnp.zeros((G, E), jnp.float32)
    disp = None
    comb = None
    kept = 0.0
    for j in range(top_k):
        oh_e = jax.nn.one_hot(idx[..., j], E, dtype=jnp.float32)   # [G,N,E]
        pos = counts[:, None, :] + jnp.cumsum(oh_e, axis=1) - oh_e
        pos_j = jnp.sum(pos * oh_e, axis=-1)                       # [G,N]
        keep_j = pos_j < C
        oh_c = jax.nn.one_hot(pos_j.astype(jnp.int32), C,
                              dtype=jnp.float32) * keep_j[..., None]
        # accumulate the [G,N,E,C] masks in the compute dtype (bf16): the
        # mask entries are exact {0,1} / gate values — halves their traffic
        m = (oh_e.astype(xg.dtype)[..., None]
             * oh_c.astype(xg.dtype)[:, :, None, :])               # [G,N,E,C]
        disp = m if disp is None else disp + m
        gj = gate[..., j, None, None].astype(xg.dtype)
        comb = gj * m if comb is None else comb + gj * m
        counts = counts + jnp.sum(oh_e, axis=1)
        kept = kept + jnp.mean(keep_j.astype(jnp.float32))

    dispb = disp
    eb = _con_experts(jnp.einsum("gnec,gnd->gecd", dispb, xg))
    gu = _con_experts(jnp.einsum("gecd,edkf->geckf", eb, p["wi"]))
    h = jax.nn.silu(gu[:, :, :, 0]) * gu[:, :, :, 1]
    out = _con_experts(jnp.einsum("gecf,efd->gecd", h, p["wo"]))
    y = jnp.einsum("gecd,gnec->gnd", out, comb)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = {
        "load_balance_loss": E * jnp.sum(me * ce),
        "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_frac": 1.0 - kept / top_k,
    }
    return y, aux


EINSUM_GROUP = 512     # tokens per routing group on the einsum path


def moe_groups(n_tokens: int, n_experts: int) -> int:
    """Routing-group count: one group per batch shard (DP-local dispatch).

    Group-local routing keeps the sort/scatter per data shard instead of a
    replicated global-token dispatch (which materializes [N_global·k, d]).
    Falls back to 1 group when tokens are few (decode) or don't divide.
    """
    from .layers import _ATTN_MESH
    if _ATTN_MESH is None:
        return 1
    mesh = _ATTN_MESH
    shards = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            shards *= mesh.shape[a]
    if n_tokens % shards or (n_tokens // shards) < 4 * n_experts:
        return 1
    return shards


def moe_apply(p: Params, x: jax.Array, top_k: int,
              capacity_factor: float = 1.25,
              n_groups: int | None = None,
              mode: str | None = None) -> tuple[jax.Array, dict]:
    """mode: "einsum" (GShard masks, pod-scale default), "sort"
    (sort-based, host/small-batch default), None = auto."""
    B, T, d = x.shape
    E = p["router"].shape[1]
    N = B * T
    from .layers import _con_groups
    if mode is None:
        mode = "einsum" if (N % EINSUM_GROUP == 0
                            and N // EINSUM_GROUP >= 16) else "sort"
    if mode == "einsum":
        G = N // EINSUM_GROUP if n_groups is None else n_groups
        Ng = N // G
        C = max(1, int(Ng * top_k / E * capacity_factor))
        xg = _con_groups(x.reshape(G, Ng, d))
        y, aux = _einsum_dispatch(p, xg, top_k, C)
        return y.reshape(B, T, d), aux
    G = n_groups if n_groups is not None else moe_groups(N, E)
    Ng = N // G
    C = max(1, int(Ng * top_k / E * capacity_factor))
    xg = _con_groups(x.reshape(G, Ng, d))
    y, aux = _grouped_dispatch(p, xg, top_k, C)
    return y.reshape(B, T, d), aux
