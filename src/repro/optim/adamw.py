"""AdamW — functional, pytree-based, ZeRO-shardable.

Moment tensors are f32 regardless of param dtype; the launcher's sharding
rules additionally shard them over the "data" axis (ZeRO-1), which is why
state lives in a flat pytree mirroring params (no fused buffer).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


def adamw_init(params: Params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw_update(grads: Params, state: AdamWState, params: Params, *,
                 lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 max_grad_norm: float | None = 1.0
                 ) -> tuple[Params, AdamWState, dict]:
    if max_grad_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * g32 * g32
        mh, vh = m / c1, v / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr_t}
    return new_params, AdamWState(step, new_m, new_v), metrics
